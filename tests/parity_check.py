"""Subprocess body for the cross-path parity suite (multi-shard half).

Runs on 4 fake host devices arranged as a (1 data x 4 model) mesh — the
acceptance gate's "4-shard CPU mesh" — and checks the three-path matrix
(docs/query_path.md):

* distributed-sparse == single-device-sparse to <= 1e-5 L1 when the widths
  cover the frontier support (incl. hub-split variants),
* both == the dense oracle at covering widths,
* truncated widths only *drop* mass (elementwise monotone) and the L1 drift
  is bounded by the dropped mass,
* the sparse exchange actually routed through the fused Pallas wrapper
  ``kernels.ops.sharded_frontier_push`` (trace-time invocation counter) —
  not a duplicated jnp path.

Exits nonzero on mismatch; tests/test_parity.py asserts the return code.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verd as verd_mod
from repro.core.distributed_engine import (
    DistConfig, build_sharded_graph, make_verd_tile_step,
)
from repro.core.index import index_from_dense
from repro.core.power_iteration import exact_ppr_dense
from repro.graphs import synthetic

EP = 4
N_PAD = 128
TOP_K = N_PAD  # cover the full support so answers densify losslessly
QT = 8


def densify_answers(vals, idx, n):
    q = vals.shape[0]
    out = np.zeros((q, n), np.float32)
    np.add.at(out, (np.arange(q)[:, None], np.asarray(idx)), np.asarray(vals))
    return out


def run_distributed(cfg, slabs, sources, ivals, iidx, mesh):
    step = make_verd_tile_step(cfg, mesh)
    with mesh:
        tv, ti = jax.jit(step)(slabs, sources, ivals, iidx)
    return densify_answers(tv, ti, cfg.n)


def main():
    mesh = jax.make_mesh((1, EP), ("data", "model"))
    g = synthetic.erdos_renyi(120, 4.0, seed=3)
    cap = verd_mod.resolve_degree_cap(g)
    base = dict(n=N_PAD, ep=EP, q_tile=QT, t_iterations=2, index_l=16,
                top_k=TOP_K, degree_cap=cap)
    cfg = DistConfig(frontier_k=N_PAD, wire_k=0, combine_wire_k=0, **base)
    slabs = build_sharded_graph(g, cfg)

    exact = exact_ppr_dense(g)
    dense_pad = np.zeros((N_PAD, N_PAD), np.float32)
    dense_pad[: g.n, : g.n] = exact
    idx = index_from_dense(jnp.asarray(dense_pad), l=cfg.index_l)
    ivals = idx.values.reshape(EP, cfg.n_shard, cfg.index_l)
    iidx = idx.indices.reshape(EP, cfg.n_shard, cfg.index_l)
    idx_small = index_from_dense(jnp.asarray(dense_pad[: g.n, : g.n]),
                                 l=cfg.index_l)
    sources = jnp.asarray([0, 3, 7, 11, 19, 23, 31, 42], jnp.int32)

    # path 1: single-device sparse (covering K)
    sp = verd_mod.verd_query_sparse(
        g, sources, idx_small, t=cfg.t_iterations, k=g.n, out_k=TOP_K
    )
    single_sparse = np.zeros((QT, N_PAD), np.float32)
    single_sparse[:, : g.n] = np.asarray(sp.densify())

    # path 2: dense oracle
    dense_ans = np.zeros((QT, N_PAD), np.float32)
    dense_ans[:, : g.n] = np.asarray(verd_mod.verd_query(
        g, sources, idx_small, t=cfg.t_iterations))

    # path 3: distributed sparse exchange, with and without hub splitting;
    # the 4-shard run must invoke the fused kernel wrapper once per VERD
    # iteration (trace time), not fall back to a jnp push
    from repro.kernels import ops as kernel_ops

    kernel_ops.reset_kernel_invocations()
    got = run_distributed(cfg, slabs, sources, ivals, iidx, mesh)
    pushes = kernel_ops.kernel_invocations().get("sharded_frontier_push", 0)
    assert pushes == cfg.t_iterations, (
        f"engine bypassed the fused kernel wrapper: {pushes} invocations, "
        f"expected {cfg.t_iterations}"
    )
    l1 = np.abs(got - single_sparse).sum(axis=1)
    assert l1.max() <= 1e-5, f"dist-sparse vs single-sparse L1={l1.max()}"
    l1d = np.abs(got - dense_ans).sum(axis=1)
    assert l1d.max() <= 1e-5, f"dist-sparse vs dense oracle L1={l1d.max()}"
    print(
        f"4-shard sparse exchange parity OK (L1={l1.max():.2e}, "
        f"fused-kernel pushes={pushes})"
    )

    for h in (1, 3):
        cfg_h = DistConfig(frontier_k=N_PAD, hub_split_degree=h, **base)
        got_h = run_distributed(cfg_h, slabs, sources, ivals, iidx, mesh)
        np.testing.assert_allclose(got_h, got, atol=1e-6)
    print("hub-split parity OK")

    # legacy dense exchange still matches the oracle (its slabs carry the
    # edge_w slab the sparse build skips)
    cfg_d = DistConfig(exchange="dense", **base)
    slabs_d = build_sharded_graph(g, cfg_d)
    got_d = run_distributed(cfg_d, slabs_d, sources, ivals, iidx, mesh)
    l1 = np.abs(got_d - dense_ans).sum(axis=1)
    assert l1.max() <= 1e-4, f"dense exchange L1={l1.max()}"
    print("dense exchange parity OK")

    # truncated wire: only drops mass, drift bounded by the dropped mass
    cfg_t = DistConfig(frontier_k=4, wire_k=4, combine_wire_k=8, **base)
    got_t = run_distributed(cfg_t, slabs, sources, ivals, iidx, mesh)
    assert (got_t <= got + 1e-6).all(), "truncation must be monotone"
    dropped = got.sum(axis=1) - got_t.sum(axis=1)
    l1 = np.abs(got - got_t).sum(axis=1)
    assert (l1 <= dropped + 1e-5).all(), (l1, dropped)
    print("truncated exchange bounded OK")


if __name__ == "__main__":
    main()
    print("ALL OK")
