"""Paper Table 2: preprocessing time and index size vs R.

Measured on the CPU-scale graph for both index builders — the sparse
streaming path (``engine="sparse"``, the default: compacted walks + top-L
sketches, peak ``O(rows * L)``) against the legacy dense-accumulator
oracle — then extrapolated analytically to the paper's billion-edge rows
(twitter-2010, uk-union) by fitting the measured positions/second (the
paper observes *sublinear* time in R; we check that too).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bench_graph, emit
from repro.configs.powerwalk import PAPER_GRAPHS
from repro.core.index import build_index, preprocessing_cost_model


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    key = jax.random.PRNGKey(2)
    out: dict = {"n": g.n, "m": g.m, "points": [], "extrapolation": []}
    r_values = [10, 100] if fast else [10, 100, 500]
    for r in r_values:
        point = {"r": r}
        for engine in ("sparse", "legacy"):
            t0 = time.perf_counter()
            idx, stats = build_index(
                g, r=r, l=max(16, min(int(r / 0.15), 1024)), key=key,
                source_batch=512, engine=engine,
            )
            dt = time.perf_counter() - t0
            rate = g.n * r / 0.15 / dt
            point[engine] = dict(
                seconds=dt, nbytes=stats["nbytes"], positions_per_s=rate,
                drop_fraction=stats["drop_fraction"],
            )
            emit(f"table2_{engine}_R{r}", dt * 1e6,
                 f"index_bytes={stats['nbytes']};positions_per_s={rate:.3e};"
                 f"drop_fraction={stats['drop_fraction']:.4f}")
        point["speedup"] = (
            point["legacy"]["seconds"] / max(point["sparse"]["seconds"], 1e-12)
        )
        out["points"].append(point)

    # analytic extrapolation to the paper's large graphs at the measured
    # rate of the default (sparse) builder
    sparse_rate = out["points"][-1]["sparse"]["positions_per_s"]
    for gname in ("twitter-2010", "uk-union"):
        gs = PAPER_GRAPHS[gname]
        for r in (10, 100, 2000):
            cm = preprocessing_cost_model(gs.n, r, step_rate=sparse_rate)
            out["extrapolation"].append(
                dict(graph=gname, r=r, est_seconds=cm["est_seconds"],
                     index_bytes=cm["index_bytes_uncapped"])
            )
            emit(
                f"table2_extrap_{gname}_R{r}", cm["est_seconds"] * 1e6,
                f"index_bytes={cm['index_bytes_uncapped']};analytic",
            )
    return out


if __name__ == "__main__":
    run()
