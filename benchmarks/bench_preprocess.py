"""Paper Table 2: preprocessing time and index size vs R.

Measured on the CPU-scale graph for both index builders — the sparse
streaming path (``engine="sparse"``, the default: compacted walks + top-L
sketches, peak ``O(rows * L)``) against the legacy dense-accumulator
oracle — then extrapolated analytically to the paper's billion-edge rows
(twitter-2010, uk-union) by fitting the measured positions/second (the
paper observes *sublinear* time in R; we check that too).

With >= 4 devices visible (``make bench-preprocess-dist`` forces a
host-simulated 4-device CPU mesh) the run also records the **sharded
builder** (``index.build_index_sharded``) in both walk-scheduling modes:
the ``dist`` section's r=16 row is the ISSUE 5 acceptance point —
respawn-mode must reach >= 2x the schedule-mode positions/sec.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bench_graph, emit, timeit
from repro.configs.powerwalk import PAPER_GRAPHS
from repro.core.index import (
    build_index, build_index_sharded, preprocessing_cost_model,
)


def _dist_section(fast: bool) -> dict:
    """Sharded builder rows: respawn- vs schedule-mode positions/sec."""
    if jax.device_count() < 4:
        return {
            "skipped": (
                f"needs >= 4 devices, have {jax.device_count()}; run "
                "`make bench-preprocess-dist`"
            )
        }
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    g = bench_graph("tiny" if fast else "wiki_like")
    points = []
    # the r=16, l=2R gate row is a *memory-budget* build (top-32 of a ~R/c
    # support — the paper's offline/online trade-off knob); the r=100 row
    # records the wide-index regime for the trajectory
    rows = [(16, 32)] if fast else [(16, 32), (100, 256)]
    for r, l in rows:
        point = {"r": r, "l": l, "gate_point": r == 16}
        for mode, respawn in (("schedule", False), ("respawn", True)):
            def build():
                idx, stats = build_index_sharded(
                    g, r=r, l=l, key=jax.random.PRNGKey(2), mesh=mesh,
                    source_batch=256, respawn=respawn,
                )
                jax.block_until_ready(idx.values)
                return stats
            stats = build()                       # compile + first run
            sec = timeit(build, warmup=0, iters=5)
            rate = g.n * r / 0.15 / sec
            point[mode] = dict(
                seconds=sec, positions_per_s=rate,
                drop_fraction=stats["drop_fraction"],
            )
            emit(f"table2_dist_{mode}_R{r}", sec * 1e6,
                 f"positions_per_s={rate:.3e};"
                 f"drop_fraction={stats['drop_fraction']:.4f}")
        point["respawn_speedup"] = (
            point["respawn"]["positions_per_s"]
            / max(point["schedule"]["positions_per_s"], 1e-12)
        )
        emit(f"table2_dist_speedup_R{r}", 0.0,
             f"respawn_speedup={point['respawn_speedup']:.2f}x")
        points.append(point)
    return dict(
        device_count=jax.device_count(),
        mesh="1x4 (data, model)",
        source_batch=256,
        gate="respawn >= 2x schedule positions/sec at the r=16 row",
        points=points,
    )


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    key = jax.random.PRNGKey(2)
    out: dict = {"n": g.n, "m": g.m, "points": [], "extrapolation": []}
    r_values = [10, 100] if fast else [10, 100, 500]
    for r in r_values:
        point = {"r": r}
        for engine in ("sparse", "legacy"):
            t0 = time.perf_counter()
            idx, stats = build_index(
                g, r=r, l=max(16, min(int(r / 0.15), 1024)), key=key,
                source_batch=512, engine=engine,
            )
            dt = time.perf_counter() - t0
            rate = g.n * r / 0.15 / dt
            point[engine] = dict(
                seconds=dt, nbytes=stats["nbytes"], positions_per_s=rate,
                drop_fraction=stats["drop_fraction"],
            )
            emit(f"table2_{engine}_R{r}", dt * 1e6,
                 f"index_bytes={stats['nbytes']};positions_per_s={rate:.3e};"
                 f"drop_fraction={stats['drop_fraction']:.4f}")
        point["speedup"] = (
            point["legacy"]["seconds"] / max(point["sparse"]["seconds"], 1e-12)
        )
        out["points"].append(point)

    # analytic extrapolation to the paper's large graphs at the measured
    # rate of the default (sparse) builder
    sparse_rate = out["points"][-1]["sparse"]["positions_per_s"]
    for gname in ("twitter-2010", "uk-union"):
        gs = PAPER_GRAPHS[gname]
        for r in (10, 100, 2000):
            cm = preprocessing_cost_model(gs.n, r, step_rate=sparse_rate)
            out["extrapolation"].append(
                dict(graph=gname, r=r, est_seconds=cm["est_seconds"],
                     index_bytes=cm["index_bytes_uncapped"])
            )
            emit(
                f"table2_extrap_{gname}_R{r}", cm["est_seconds"] * 1e6,
                f"index_bytes={cm['index_bytes_uncapped']};analytic",
            )

    # sharded builder rows (host-simulated mesh; skipped gracefully when
    # the process sees fewer than 4 devices)
    out["dist"] = _dist_section(fast)
    return out


if __name__ == "__main__":
    run()
