"""Paper Table 2: preprocessing time and index size vs R.

Measured on the CPU-scale graph; the paper's billion-edge rows
(twitter-2010, uk-union) are reported analytically by fitting the measured
positions/second of the bulk walk engine (the paper observes *sublinear*
time in R — we check that too).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bench_graph, emit
from repro.configs.powerwalk import PAPER_GRAPHS
from repro.core.index import build_index, preprocessing_cost_model


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    key = jax.random.PRNGKey(2)
    out = {}
    rate = None
    r_values = [10, 100] if fast else [10, 100, 500]
    for r in r_values:
        t0 = time.perf_counter()
        idx, stats = build_index(
            g, r=r, l=max(16, min(int(r / 0.15), 1024)), key=key,
            source_batch=512,
        )
        dt = time.perf_counter() - t0
        positions = g.n * r / 0.15
        rate = positions / dt
        out[r] = dict(seconds=dt, nbytes=stats["nbytes"], rate=rate)
        emit(f"table2_R{r}", dt * 1e6,
             f"index_bytes={stats['nbytes']};positions_per_s={rate:.3e}")

    # analytic extrapolation to the paper's large graphs at measured rate
    for gname in ("twitter-2010", "uk-union"):
        gs = PAPER_GRAPHS[gname]
        for r in (10, 100, 2000):
            cm = preprocessing_cost_model(gs.n, r, step_rate=rate)
            emit(
                f"table2_extrap_{gname}_R{r}", cm["est_seconds"] * 1e6,
                f"index_bytes={cm['index_bytes_uncapped']};analytic",
            )
    return out


if __name__ == "__main__":
    run()
