"""Walk-engine throughput (the DrunkardMob comparison, paper Section 3.1).

Reports positions/second of the bulk walk engine — the number the paper
quotes against Spark (1728.2 s vs 2967 s for R=100 on twitter-2010).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_graph, emit, timeit
from repro.core.walks import simulate_walks, walks_for_sources


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    key = jax.random.PRNGKey(4)
    out = {}
    for n_src, r in ((256, 10), (256, 100)):
        sources = jnp.arange(n_src, dtype=jnp.int32)
        ws, wr = walks_for_sources(sources, r)

        def go():
            return simulate_walks(
                g, ws, wr, key, n_rows=n_src, max_steps=64
            ).moves.sum()

        sec = timeit(go, iters=2)
        positions = float(go())
        rate = positions / sec
        out[(n_src, r)] = rate
        emit(f"walks_S{n_src}_R{r}", sec * 1e6,
             f"positions={positions:.0f};per_s={rate:.3e}")
    return out


if __name__ == "__main__":
    run()
