"""Walk-engine throughput (the DrunkardMob comparison, paper Section 3.1).

Reports positions/second — useful counted walk positions per wall-clock
second — for both offline walk engines:

* ``legacy``: ``simulate_walks`` — fixed-width ``max_steps`` scan over every
  walk slot, dense ``f32[rows, n]`` count accumulators.
* ``sparse``: ``simulate_walks_sparse`` — live-walk compaction (static
  ``(1-c)^t`` bucket schedule) + per-row top-L count sketches.

The headline point is the acceptance gate: the 100k-class graph
(``rmat(17)``, n = 131072 exactly), ``R=32`` on CPU, where the sparse
engine must record >= 5x the legacy positions/sec.  ``state`` bytes are
the analytic per-engine accumulator footprints — the dense pair is what
stops ``build_index`` from scaling past ``f32[rows, n]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_graph, emit, timeit
from repro.core.walks import (
    compaction_schedule,
    simulate_walks,
    simulate_walks_sparse,
    walks_for_sources,
)


def legacy_state_bytes(rows: int, n: int) -> int:
    """fp + ep dense accumulators (the engine's dominant footprint)."""
    return rows * n * 4 * 2


def sparse_state_bytes(rows: int, r: int, l: int, fold_width: int) -> int:
    """fp sketch + pending event buffer + the widest walk-slot round."""
    schedule = compaction_schedule(r)
    return rows * (l * 8 + fold_width * 8 + schedule[0] * 5)


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "ppr_100k")
    key = jax.random.PRNGKey(4)
    out: dict = {"n": g.n, "m": g.m, "points": []}
    points = ((64, 16),) if fast else ((256, 32), (256, 100))
    for n_src, r in points:
        sources = jnp.arange(n_src, dtype=jnp.int32)
        ws, wr = walks_for_sources(sources, r)
        l = min(g.n, int(r / 0.15) + 32)
        fold_width = max(4 * l, 512)

        def legacy():
            return simulate_walks(
                g, ws, wr, key, n_rows=n_src, max_steps=64
            ).moves.sum()

        def sparse():
            return simulate_walks_sparse(
                g, sources, r, key, l=l, ep_l=0, fold_width=fold_width
            ).moves.sum()

        point = {"rows": n_src, "r": r, "l": l}
        for name, fn, state in (
            ("legacy", legacy, legacy_state_bytes(n_src, g.n)),
            ("sparse", sparse,
             sparse_state_bytes(n_src, r, l, fold_width)),
        ):
            # one un-timed call compiles AND yields the position count (the
            # engines are deterministic under the fixed key)
            positions = float(fn())
            sec = timeit(fn, warmup=0, iters=2)
            rate = positions / sec
            point[name] = dict(
                wall_s=sec, positions=positions, positions_per_s=rate,
                state_bytes=state,
            )
            emit(f"walks_{name}_S{n_src}_R{r}", sec * 1e6,
                 f"positions={positions:.0f};per_s={rate:.3e};"
                 f"state_bytes={state}")
        point["speedup"] = (
            point["sparse"]["positions_per_s"]
            / max(point["legacy"]["positions_per_s"], 1e-12)
        )
        point["state_reduction"] = (
            point["legacy"]["state_bytes"]
            / max(point["sparse"]["state_bytes"], 1)
        )
        emit(f"walks_speedup_S{n_src}_R{r}", 0.0,
             f"speedup={point['speedup']:.2f}x;"
             f"state_reduction={point['state_reduction']:.1f}x")
        out["points"].append(point)
    return out


if __name__ == "__main__":
    run()
