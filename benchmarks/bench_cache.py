"""Answer-cache benchmark: Zipf hot-seed traffic x cache size.

Drives the service with ``zipf_seed_workload`` (hot weighted seed sets,
spelled with permuted seeds and rescaled weights so hits go through
canonicalization) and sweeps skew x cache capacity at the n=100k / K=512
reference point.  For each cell it measures the closed-loop capacity with
a *warm* cache, then an open-loop rate sweep around that capacity, and
records the sustained knee + hit rate — the persisted trajectory is how
much the answer cache moves the saturation knee versus cache-off
(``knee_speedup_cache``; acceptance gate >= 1.5x at skew 1.1).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_query import _random_index
from benchmarks.bench_serving import SUSTAIN_FRACTION, _knee, _warmup
from benchmarks.common import emit
from repro.core.query import QueryConfig
from repro.graphs import synthetic
from repro.serving import PPRService, PipelineConfig, ServiceConfig
from repro.serving.batching import BatchingConfig
from repro.serving.cache import CacheConfig
from repro.serving.loadgen import (run_closed_loop, run_open_loop,
                                   zipf_seed_workload)

FULL = dict(n=100_000, avg_deg=8.0, L=32, K=512, top_k=100, t=2,
            max_seeds=4, max_batch=256, min_pad=64, max_wait_s=0.010,
            depth=2, requests=2048, pool=1024, singles_fraction=0.25,
            skews=(0.8, 1.1, 1.4), capacities=(0, 128, 512),
            gate_skew=1.1, rate_grid=(0.6, 0.9, 1.1, 1.4))
FAST = dict(n=8_192, avg_deg=8.0, L=16, K=128, top_k=50, t=2,
            max_seeds=4, max_batch=32, min_pad=16, max_wait_s=0.005,
            depth=2, requests=240, pool=96, singles_fraction=0.25,
            skews=(1.1,), capacities=(0, 64),
            gate_skew=1.1, rate_grid=(0.8, 1.2))


def _make_service(g, idx, p: dict, capacity: int) -> PPRService:
    cfg = ServiceConfig(
        query=QueryConfig(
            mode="powerwalk", t_iterations=p["t"], top_k=p["top_k"],
            frontier_k=p["K"], frontier_path="sparse",
            max_seeds=p["max_seeds"],
        ),
        batching=BatchingConfig(
            max_batch=p["max_batch"], max_wait_s=p["max_wait_s"],
            min_pad=p["min_pad"],
        ),
        pipeline=PipelineConfig(depth=p["depth"], dispatch="fused"),
        cache=CacheConfig(capacity=capacity),
    )
    return PPRService(g, idx, cfg)


def _point(stats: dict) -> dict:
    return dict(
        offered_qps=stats["offered_qps"], qps=stats["qps"],
        latency_p50=stats["latency_p50"], latency_p99=stats["latency_p99"],
        served=stats["served"], batches=stats["batches"],
        pad_fraction=stats["pad_fraction"],
        cache_hit_rate=stats["cache_hit_rate"],
        cache_served=stats["cache_served"],
        cache_evictions=stats["cache_evictions"],
    )


def run(fast: bool = False) -> dict:
    p = FAST if fast else FULL
    g = synthetic.erdos_renyi(p["n"], p["avg_deg"], seed=5)
    idx = _random_index(g.n, p["L"], jax.random.PRNGKey(7))

    out: dict = dict(
        reference=dict(
            n=p["n"], K=p["K"], L=p["L"], top_k=p["top_k"], t=p["t"],
            max_seeds=p["max_seeds"], max_batch=p["max_batch"],
            depth=p["depth"], requests=p["requests"], pool=p["pool"],
            singles_fraction=p["singles_fraction"],
            sustain_fraction=SUSTAIN_FRACTION,
        ),
        closed_loop={}, open_loop={}, knee={}, hit_rate={},
    )

    for skew in p["skews"]:
        workload = zipf_seed_workload(
            g.n, p["requests"], skew=skew, max_seeds=p["max_seeds"],
            pool=p["pool"], singles_fraction=p["singles_fraction"],
            seed=13,
        )
        for capacity in p["capacities"]:
            cell = f"skew{skew:g}_cap{capacity}"
            svc = _make_service(g, idx, p, capacity)
            _warmup(svc, p)
            # warm pass: measures closed-loop capacity *and* leaves the
            # cache warm (reset_stats zeros counters, entries persist) —
            # the acceptance gate is a warm-cache knee vs cache-off
            _, stats = run_closed_loop(svc, workload)
            capacity_qps = stats["qps"]
            out["closed_loop"][cell] = _point(stats)
            emit(f"cache_closed_{cell}", 1e6 / max(capacity_qps, 1e-9),
                 f"qps={capacity_qps:.1f};"
                 f"hit={stats['cache_hit_rate']:.2f}")

            points = []
            for frac in p["rate_grid"]:
                offered = frac * capacity_qps
                svc.reset_stats()
                _, stats = run_open_loop(svc, workload, qps=offered)
                points.append(_point(stats))
                emit(f"cache_open_{cell}_r{frac:g}",
                     1e6 / max(stats["qps"], 1e-9),
                     f"offered={offered:.1f};qps={stats['qps']:.1f};"
                     f"hit={stats['cache_hit_rate']:.2f};"
                     f"p99={stats['latency_p99']*1e3:.1f}ms")
            out["open_loop"][cell] = points
            out["knee"][cell] = _knee(points)
            out["hit_rate"][cell] = max(pt["cache_hit_rate"] for pt in points)

    # -- the acceptance gate: warm-cache knee vs cache-off at gate_skew -----
    gate = f"skew{p['gate_skew']:g}"
    base = out["knee"][f"{gate}_cap0"]["knee_qps"]
    best_cap = max(
        (c for c in p["capacities"] if c > 0),
        key=lambda c: out["knee"][f"{gate}_cap{c}"]["knee_qps"],
    )
    best = out["knee"][f"{gate}_cap{best_cap}"]["knee_qps"]
    out["knee_speedup_cache"] = best / max(base, 1e-9)
    out["knee_best_capacity"] = best_cap
    out["gate_skew"] = p["gate_skew"]
    emit("cache_knee_speedup", 0.0,
         f"cap{best_cap}_{best:.1f}qps_vs_cap0_{base:.1f}qps;"
         f"x{out['knee_speedup_cache']:.2f}")
    return out
