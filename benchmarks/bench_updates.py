"""Evolving-graph maintenance benchmark: incremental repair vs rebuild.

Drives a built index through a sequence of edge-update batches with
``core.updates.apply_updates`` and records, per batch and in aggregate:

* **update throughput** — edges applied per second of wall time (graph
  mutation + invalidation planning + chunk repair, end to end);
* **resample accounting** — walk positions resampled by the repair vs the
  positions a from-scratch rebuild would sweep.  The headline gate is the
  aggregate over the whole sequence: incremental maintenance across all
  batches must resample >= 10x fewer positions than rebuilding after each
  batch (``gate_resample``);
* **answer drift** — mean L1 of densified index rows against
  power-iteration ground truth on the final mutated graph, for the
  incremental index and for a from-scratch rebuild (same key).  The gate
  is ``drift_incremental <= 2 * drift_rebuild`` (``gate_drift``); the
  chunk-keyed repair actually achieves bitwise equality, recorded as
  ``index_l1_vs_rebuild == 0``.

Batches keep the edge count constant (each inserts E fresh uniform edges
and deletes the E edges the previous batch inserted, seeded by a pre-build
pool), so every repair reuses one jit trace — the steady-state regime an
evolving-graph service actually runs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import updates
from repro.core.graph import apply_edge_updates
from repro.core.index import build_index
from repro.core.power_iteration import power_iteration
from repro.graphs import synthetic

FULL = dict(n=1 << 15, avg_deg=8.0, seed=5, r=16, l=32, c=0.25,
            source_batch=8, max_steps=64, respawn=True,
            batches=8, edges_per_batch=4, probes=32, pi_iters=100)
FAST = dict(n=1 << 11, avg_deg=8.0, seed=5, r=8, l=16, c=0.25,
            source_batch=16, max_steps=64, respawn=True,
            batches=3, edges_per_batch=4, probes=8, pi_iters=60)


def _uniform_edges(rng, n, k):
    return rng.integers(0, n, size=(k, 2), dtype=np.int64)


def _row_l1_vs_exact(index, exact, probes):
    """Mean L1 between densified index rows and ground-truth PPR rows."""
    vals = np.asarray(index.values)
    idxs = np.asarray(index.indices)
    n = exact.shape[1]
    errs = []
    for j, u in enumerate(probes):
        dense = np.zeros(n, np.float64)
        np.add.at(dense, idxs[u], vals[u].astype(np.float64))
        errs.append(np.abs(dense - np.asarray(exact[j], np.float64)).sum())
    return float(np.mean(errs))


def run(fast: bool = False) -> dict:
    p = FAST if fast else FULL
    rng = np.random.default_rng(p["seed"])
    key = jax.random.PRNGKey(p["seed"])
    e = p["edges_per_batch"]

    base = synthetic.rmat(int(np.log2(p["n"])), avg_deg=p["avg_deg"],
                          seed=p["seed"])
    # pre-build insert pool: batch 0's deletes come from here, so every
    # delete in the sequence removes a uniformly-drawn prior insert and
    # the edge count never changes (one jit trace for all repairs)
    pool = _uniform_edges(rng, base.n, e)
    g, _ = apply_edge_updates(base, inserts=pool)

    t0 = time.perf_counter()
    m, build_stats = updates.build_maintainable_index(
        g, p["r"], p["l"], key, c=p["c"], max_steps=p["max_steps"],
        source_batch=p["source_batch"], respawn=p["respawn"])
    jax.block_until_ready(m.index.values)
    build_s = time.perf_counter() - t0
    touch_bits = m.touch.n_bits

    batches = []
    total_resampled = 0.0
    total_rebuild_equiv = 0.0
    for b in range(p["batches"]):
        ins = _uniform_edges(rng, g.n, e)
        t0 = time.perf_counter()
        g, m, rep = updates.apply_updates(m, g, inserts=ins, deletes=pool)
        jax.block_until_ready(m.index.values)
        wall = time.perf_counter() - t0
        pool = ins
        total_resampled += rep["resampled_positions"]
        total_rebuild_equiv += rep["rebuild_positions"]
        edges = rep["edges_inserted"] + rep["edges_deleted"]
        batches.append(dict(
            batch=b, wall_s=wall, edges=edges,
            edges_per_sec=edges / max(wall, 1e-9),
            dirty_rows=rep["dirty_rows"],
            repaired_chunks=rep["repaired_chunks"],
            total_chunks=rep["total_chunks"],
            resampled_positions=rep["resampled_positions"],
            resample_ratio=rep["resample_ratio"],
        ))
        emit(f"updates/batch{b}", wall * 1e6,
             f"edges_per_sec={edges / max(wall, 1e-9):.0f} "
             f"dirty={rep['dirty_rows']} "
             f"chunks={rep['repaired_chunks']}/{rep['total_chunks']}")

    # from-scratch rebuild on the final graph, same key: the baseline the
    # incremental path replaces (and must match)
    t0 = time.perf_counter()
    rebuilt, _ = build_index(
        g, p["r"], p["l"], key, engine="sparse", c=p["c"],
        max_steps=p["max_steps"], source_batch=p["source_batch"],
        respawn=p["respawn"], touch_bits=touch_bits)
    jax.block_until_ready(rebuilt.values)
    rebuild_s = time.perf_counter() - t0

    index_l1 = float(jnp.abs(m.index.values - rebuilt.values).sum())
    bitwise = bool(
        jnp.array_equal(m.index.values, rebuilt.values)
        and jnp.array_equal(m.index.indices, rebuilt.indices))

    probes = np.sort(rng.choice(g.n, size=p["probes"], replace=False))
    exact = power_iteration(
        g, jnp.asarray(probes, jnp.int32), n_iter=p["pi_iters"], c=p["c"])
    drift_inc = _row_l1_vs_exact(m.index, exact, probes)
    drift_reb = _row_l1_vs_exact(rebuilt, exact, probes)
    drift_ratio = drift_inc / max(drift_reb, 1e-12)

    agg_ratio = total_rebuild_equiv / max(total_resampled, 1e-9)
    mean_eps = float(np.mean([b["edges_per_sec"] for b in batches]))
    emit("updates/aggregate", 0.0,
         f"resample_ratio={agg_ratio:.1f} drift_ratio={drift_ratio:.3f} "
         f"bitwise={bitwise}")

    return dict(
        params={k: v for k, v in p.items()},
        touch_bits=touch_bits,
        touch_mb=m.touch.nbytes / 1e6,
        build_s=build_s,
        rebuild_s=rebuild_s,
        batches=batches,
        mean_edges_per_sec=mean_eps,
        total_resampled_positions=total_resampled,
        total_rebuild_positions=total_rebuild_equiv,
        resample_ratio=agg_ratio,
        index_l1_vs_rebuild=index_l1,
        bitwise_equal_rebuild=bitwise,
        drift_incremental=drift_inc,
        drift_rebuild=drift_reb,
        drift_ratio=drift_ratio,
        gate_resample=bool(agg_ratio >= 10.0),
        gate_drift=bool(drift_ratio <= 2.0),
    )
