"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

On CPU the Pallas interpreter is a correctness tool, not a speed tool, so
the timing signal here is the *jnp* path (what the XLA CPU backend does
with the same math) plus a correctness gate on the kernel.  On TPU the
same harness times the compiled kernels (interpret=False).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, emit, timeit
from repro.core.graph import push_forward
from repro.graphs import formats
from repro.kernels import frontier_push as push_mod
from repro.kernels import index_combine as comb_mod
from repro.kernels import ops, ref


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny")
    ell = formats.to_ell_chunks(g, k=16, pad_rows_to=256)
    rng = np.random.default_rng(0)
    q = 8
    f = jnp.asarray(rng.random((q, g.n)), jnp.float32)
    out = {}

    # frontier push: edge-parallel segment-sum vs chunked-ELL pull
    t_edge = timeit(lambda: push_forward(g, f))
    t_ell = timeit(lambda: formats.ell_pull(ell, f))
    emit("kernel_push_edge_parallel", t_edge * 1e6, f"n={g.n};m={g.m}")
    emit("kernel_push_ell_jnp", t_ell * 1e6, f"rows={ell.rows};k={ell.k}")

    got = ops.ell_push(f, ell, interpret=True)
    want = push_forward(g, f)
    err = float(jnp.abs(got - want).max())
    emit("kernel_push_pallas_interpret", 0.0, f"max_err={err:.2e}")
    out["push_err"] = err

    # index combine
    n, l = g.n, 32
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    t_ref = timeit(lambda: ref.index_combine_ref(s, f, vals, idx))
    emit("kernel_combine_jnp", t_ref * 1e6, f"n={n};L={l}")
    got = ops.index_combine(s, f, vals, idx, interpret=True)
    err = float(jnp.abs(got - ref.index_combine_ref(s, f, vals, idx)).max())
    emit("kernel_combine_pallas_interpret", 0.0, f"max_err={err:.2e}")
    out["combine_err"] = err

    # embedding bag
    b, bag, v, d = 256, 8, 4096, 128
    ids = jnp.asarray(rng.integers(0, v, (b, bag)), jnp.int32)
    mask = jnp.ones((b, bag), jnp.float32)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    t_ref = timeit(lambda: ref.embedding_bag_ref(ids, mask, table))
    emit("kernel_bag_jnp", t_ref * 1e6, f"b={b};bag={bag};v={v};d={d}")
    got = ops.embedding_bag(ids, mask, table, interpret=True)
    err = float(jnp.abs(got - ref.embedding_bag_ref(ids, mask, table)).max())
    emit("kernel_bag_pallas_interpret", 0.0, f"max_err={err:.2e}")
    out["bag_err"] = err
    out.update(run_vmem_report(fast=fast))
    return out


# ---------------------------------------------------------------------------
# per-grid-step VMEM: whole-array-block kernels (pre-HBM-residency) vs the
# DMA-gather kernels (CSR/index arrays stay in HBM, only tiles in VMEM)
# ---------------------------------------------------------------------------

def run_vmem_report(fast: bool = False) -> dict:
    """Per-step VMEM bytes of the sparse-path kernels, before/after HBM
    residency.

    ``before`` is what the legacy kernels held resident per grid step (the
    whole CSR / index arrays as input blocks — O(nnz)); ``after`` is the
    DMA-gather layout (frontier tiles + gather scratch, O(q_tile * K *
    degree_cap) — independent of n and nnz).  Analytic from the block
    shapes (exact: the buffers are fixed width), so the report also covers
    pod-scale configs this container cannot allocate.  The 16 MB line is
    the per-core VMEM budget the compiled (interpret=False) kernels must
    fit; the ``hub`` point deliberately shows a config whose gather scratch
    still overflows it — degree truncation / smaller q_tile remains the
    operator's knob there even with HBM residency.
    """
    vmem_budget = 16 * 1024 * 1024
    # (label, n, m, q_tile, K, k_out, degree_cap, hub_split)
    points = [("tiny", 4_096, 32_768, 8, 256, 200, 64, 0)]
    if not fast:
        points += [
            ("wiki", 100_000, 1_000_000, 8, 512, 200, 48, 0),
            ("hub", 1_000_000, 16_000_000, 1, 512, 200, 16_384, 128),
        ]
    out = {}
    for label, n, m, q_tile, k, k_out, cap, split in points:
        after = push_mod.vmem_bytes(
            q_tile, k, k_out, degree_cap=cap, hub_split_degree=split
        )
        before = push_mod.vmem_bytes_legacy(
            q_tile, k, k_out, n=n, m=m, degree_cap=cap,
            hub_split_degree=split,
        )
        out[("push_vmem", label)] = dict(before=before, after=after)
        emit(
            f"kernel_push_vmem_{label}",
            float(after),
            f"n={n};m={m};before_B={before:.3e};after_B={after:.3e};"
            f"reduction={before / after:.1f}x;"
            f"fits_16MB={'yes' if after <= vmem_budget else 'NO'}",
        )
        l = 32
        c_after = comb_mod.sparse_vmem_bytes(q_tile, k, k, l, k_out)
        c_before = comb_mod.sparse_vmem_bytes_legacy(
            q_tile, k, k, l, k_out, n=n
        )
        out[("combine_vmem", label)] = dict(before=c_before, after=c_after)
        emit(
            f"kernel_combine_vmem_{label}",
            float(c_after),
            f"n={n};L={l};before_B={c_before:.3e};after_B={c_after:.3e};"
            f"reduction={c_before / c_after:.1f}x;"
            f"fits_16MB={'yes' if c_after <= vmem_budget else 'NO'}",
        )
    return out


if __name__ == "__main__":
    run()
