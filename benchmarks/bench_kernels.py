"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

On CPU the Pallas interpreter is a correctness tool, not a speed tool, so
the timing signal here is the *jnp* path (what the XLA CPU backend does
with the same math) plus a correctness gate on the kernel.  On TPU the
same harness times the compiled kernels (interpret=False).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, emit, timeit
from repro.core.graph import push_forward
from repro.graphs import formats
from repro.kernels import ops, ref


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny")
    ell = formats.to_ell_chunks(g, k=16, pad_rows_to=256)
    rng = np.random.default_rng(0)
    q = 8
    f = jnp.asarray(rng.random((q, g.n)), jnp.float32)
    out = {}

    # frontier push: edge-parallel segment-sum vs chunked-ELL pull
    t_edge = timeit(lambda: push_forward(g, f))
    t_ell = timeit(lambda: formats.ell_pull(ell, f))
    emit("kernel_push_edge_parallel", t_edge * 1e6, f"n={g.n};m={g.m}")
    emit("kernel_push_ell_jnp", t_ell * 1e6, f"rows={ell.rows};k={ell.k}")

    got = ops.ell_push(f, ell, interpret=True)
    want = push_forward(g, f)
    err = float(jnp.abs(got - want).max())
    emit("kernel_push_pallas_interpret", 0.0, f"max_err={err:.2e}")
    out["push_err"] = err

    # index combine
    n, l = g.n, 32
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    t_ref = timeit(lambda: ref.index_combine_ref(s, f, vals, idx))
    emit("kernel_combine_jnp", t_ref * 1e6, f"n={n};L={l}")
    got = ops.index_combine(s, f, vals, idx, interpret=True)
    err = float(jnp.abs(got - ref.index_combine_ref(s, f, vals, idx)).max())
    emit("kernel_combine_pallas_interpret", 0.0, f"max_err={err:.2e}")
    out["combine_err"] = err

    # embedding bag
    b, bag, v, d = 256, 8, 4096, 128
    ids = jnp.asarray(rng.integers(0, v, (b, bag)), jnp.int32)
    mask = jnp.ones((b, bag), jnp.float32)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    t_ref = timeit(lambda: ref.embedding_bag_ref(ids, mask, table))
    emit("kernel_bag_jnp", t_ref * 1e6, f"b={b};bag={bag};v={v};d={d}")
    got = ops.embedding_bag(ids, mask, table, interpret=True)
    err = float(jnp.abs(got - ref.embedding_bag_ref(ids, mask, table)).max())
    emit("kernel_bag_pallas_interpret", 0.0, f"max_err={err:.2e}")
    out["bag_err"] = err
    return out


if __name__ == "__main__":
    run()
