"""Serving-path benchmark: open-loop QPS sweep over the async pipeline.

Drives ``PPRService`` with the open-loop load generator at a grid of
offered rates and records what clients would see: p50/p99 latency vs
offered QPS, the saturation knee (highest offered rate the service still
sustains), the batch-size histogram the batcher actually formed, and a
pipeline-depth sweep.  ``depth=1, dispatch=legacy`` reproduces the PR-5
blocking ``poll()`` and is the baseline; the acceptance gate is sustained
knee throughput >= 2x that baseline at the n=100k / K=512 reference point
(same reference as bench_query's sparse sweep).

Warmup dispatches cover every padded jit shape the batcher can form
(``min_pad .. max_batch`` powers of two) before any measurement, and the
harness additionally reports ``wall_s_excl_first_batch`` so trajectories
are never dominated by compile time.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_query import _random_index
from benchmarks.common import emit
from repro.core.query import QueryConfig
from repro.graphs import synthetic
from repro.serving import PPRService, PipelineConfig, ServiceConfig
from repro.serving.batching import BatchingConfig
from repro.serving.loadgen import run_closed_loop, run_open_loop

# sustained = achieved within this fraction of offered (open-loop knee rule)
SUSTAIN_FRACTION = 0.92

FULL = dict(n=100_000, avg_deg=8.0, L=32, K=512, top_k=100, t=2,
            max_batch=256, min_pad=64, max_wait_s=0.010, requests=2048,
            depths=(1, 2, 4), rate_grid=(0.6, 0.9, 1.1, 1.4))
FAST = dict(n=8_192, avg_deg=8.0, L=16, K=128, top_k=50, t=2,
            max_batch=32, min_pad=16, max_wait_s=0.005, requests=160,
            depths=(1, 2), rate_grid=(0.8, 1.2))


def _make_service(g, idx, p: dict, depth: int, dispatch: str) -> PPRService:
    cfg = ServiceConfig(
        query=QueryConfig(
            mode="powerwalk", t_iterations=p["t"], top_k=p["top_k"],
            frontier_k=p["K"], frontier_path="sparse",
        ),
        batching=BatchingConfig(
            max_batch=p["max_batch"], max_wait_s=p["max_wait_s"],
            min_pad=p["min_pad"],
        ),
        pipeline=PipelineConfig(depth=depth, dispatch=dispatch),
    )
    return PPRService(g, idx, cfg)


def _warmup(svc: PPRService, p: dict) -> None:
    """Compile every padded batch shape the buffer can form, then zero the
    counters so measurements see a warm service only.  Iterates the
    batcher's own closed shape set (``BatchingConfig.padded_shapes``) —
    the old pow2 walk missed the bucketed quantum-multiple widths (e.g.
    192 at max_batch=256), so those compiled mid-measurement."""
    for shape in svc.cfg.batching.padded_shapes():
        for v in range(shape):
            svc.submit(v % svc.engine.graph.n)
        svc.poll(force=True)
    svc.reset_stats()


def _point(stats: dict) -> dict:
    """The per-measurement slice of stats the JSON trajectory keeps."""
    return dict(
        offered_qps=stats["offered_qps"], qps=stats["qps"],
        qps_excl_first_batch=stats["qps_excl_first_batch"],
        latency_p50=stats["latency_p50"], latency_p99=stats["latency_p99"],
        mean_latency=stats["mean_latency"], served=stats["served"],
        batches=stats["batches"], pad_fraction=stats["pad_fraction"],
        batch_hist=stats["batch_hist"],
        in_flight_peak=stats["pipeline_in_flight_peak"],
        queue_full_stalls=stats["pipeline_queue_full_stalls"],
    )


def _knee(points: list) -> dict:
    """Highest sustained point of one open-loop sweep: the largest offered
    rate where achieved throughput kept up (SUSTAIN_FRACTION), else the
    best achieved rate (fully saturated sweep)."""
    sustained = [p for p in points
                 if p["qps"] >= SUSTAIN_FRACTION * p["offered_qps"]]
    pool = sustained or points
    best = max(pool, key=lambda p: p["qps"])
    return dict(knee_qps=best["qps"], offered_qps=best["offered_qps"],
                latency_p99=best["latency_p99"], sustained=bool(sustained))


def run(fast: bool = False) -> dict:
    p = FAST if fast else FULL
    g = synthetic.erdos_renyi(p["n"], p["avg_deg"], seed=5)
    idx = _random_index(g.n, p["L"], jax.random.PRNGKey(7))
    rng = np.random.default_rng(11)
    workload = rng.integers(0, g.n, size=p["requests"]).tolist()

    configs = [("legacy_d1", 1, "legacy")]
    configs += [(f"fused_d{d}", d, "fused") for d in p["depths"]]

    out: dict = dict(
        reference=dict(n=p["n"], K=p["K"], L=p["L"], top_k=p["top_k"],
                       t=p["t"], max_batch=p["max_batch"],
                       max_wait_s=p["max_wait_s"], requests=p["requests"]),
        closed_loop={}, open_loop={}, knee={}, depth_sweep={},
    )

    # -- closed-loop capacity per config (sets each open-loop rate grid) ----
    capacity = {}
    services = {}
    for name, depth, dispatch in configs:
        svc = _make_service(g, idx, p, depth, dispatch)
        _warmup(svc, p)
        _, stats = run_closed_loop(svc, workload)
        # the service is warm (all jit shapes compiled by _warmup), so the
        # plain wall-clock qps is the honest capacity; excl_first_batch
        # only matters on cold services
        capacity[name] = stats["qps"]
        services[name] = svc
        out["closed_loop"][name] = _point(stats)
        if dispatch == "fused":
            out["depth_sweep"][str(depth)] = stats["qps"]
        emit(f"serving_closed_{name}", 1e6 / max(stats["qps"], 1e-9),
             f"qps={stats['qps']:.1f};p99={stats['latency_p99']*1e3:.1f}ms")

    # -- open-loop sweep: offered rate grid scaled to each config's own
    # closed-loop capacity so every sweep brackets its knee -----------------
    for name, depth, dispatch in configs:
        svc = services[name]
        points = []
        for frac in p["rate_grid"]:
            offered = frac * capacity[name]
            svc.reset_stats()
            _, stats = run_open_loop(svc, workload, qps=offered)
            points.append(_point(stats))
            emit(f"serving_open_{name}_r{frac:g}",
                 1e6 / max(stats["qps"], 1e-9),
                 f"offered={offered:.1f};qps={stats['qps']:.1f};"
                 f"p99={stats['latency_p99']*1e3:.1f}ms")
        out["open_loop"][name] = points
        out["knee"][name] = _knee(points)

    # -- the acceptance gate: pipelined knee vs blocking-baseline knee ------
    base = out["knee"]["legacy_d1"]["knee_qps"]
    best_name = max((n for n, _, d in configs if d == "fused"),
                    key=lambda n: out["knee"][n]["knee_qps"])
    best = out["knee"][best_name]["knee_qps"]
    out["knee_speedup_vs_blocking"] = best / max(base, 1e-9)
    out["knee_best_config"] = best_name
    emit("serving_knee_speedup", 0.0,
         f"best={best_name};{best:.1f}qps_vs_{base:.1f}qps;"
         f"x{out['knee_speedup_vs_blocking']:.2f}")
    return out
