"""Shared benchmark utilities: graphs, ground truth, timing, CSV output."""

from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.graph import Graph, bucket_sample_sources
from repro.core.power_iteration import power_iteration
from repro.graphs import synthetic

_ROWS = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Print one CSV row: name,us_per_call,derived."""
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (seconds) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


_GRAPH_CACHE: Dict[str, object] = {}


def bench_graph(name: str = "wiki_like") -> Graph:
    """wiki-Vote-scale synthetic power-law graph (the paper's small tier)."""
    if name not in _GRAPH_CACHE:
        if name == "wiki_like":
            _GRAPH_CACHE[name] = synthetic.rmat(12, avg_deg=12.0, seed=1)
        elif name == "tiny":
            _GRAPH_CACHE[name] = synthetic.rmat(9, avg_deg=8.0, seed=2)
        elif name == "ppr_100k":
            # the 100k-class acceptance point of the walk/preprocess
            # benches; rmat(17) is n = 2^17 = 131072 exactly
            _GRAPH_CACHE[name] = synthetic.rmat(17, avg_deg=8.0, seed=3)
        else:
            raise KeyError(name)
    return _GRAPH_CACHE[name]


def ground_truth(graph: Graph, sources: np.ndarray) -> jnp.ndarray:
    """PI to residual ~1e-7 (the paper's ground-truth method)."""
    return power_iteration(
        graph, jnp.asarray(sources, jnp.int32), n_iter=100
    )


def paper_sources(graph: Graph, per_bucket: int = 10, seed: int = 0) -> np.ndarray:
    """Paper Section 4.2: 10 random vertices per out-degree bucket."""
    return bucket_sample_sources(graph, per_bucket=per_bucket, seed=seed)


def rag(exact, approx, k: int) -> float:
    return metrics.mean_rag(exact, approx, k)
