"""Paper Table 3 + Figure 6: online query latency vs batch size and method,
plus the dense-vs-sparse frontier-path sweep and the distributed exchange
wire-byte report (docs/query_path.md).

Methods: PI, online MCFP, FPPR (direct index lookup), PowerWalk at
R in {0, 10, 100}.  Batch sizes scaled to the CPU-tier graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, emit, timeit
from repro.core.distributed_engine import (
    DistConfig, exchange_bytes_per_iteration,
)
from repro.core.index import PPRIndex, build_index
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.graphs import synthetic


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    key = jax.random.PRNGKey(3)
    rng = np.random.default_rng(0)
    out = {}

    idx10, _ = build_index(g, r=10, l=67, key=key, source_batch=512)
    idx100, _ = build_index(g, r=100, l=256, key=key, source_batch=512)

    engines = {
        "pi": BatchQueryEngine(g, None, QueryConfig(
            mode="pi", pi_iterations=50, top_k=50)),
        "mcfp_online": BatchQueryEngine(g, None, QueryConfig(
            mode="mcfp", r_online=1000, top_k=50)),
        "fppr": BatchQueryEngine(g, idx100, QueryConfig(
            mode="fppr", top_k=50)),
        "powerwalk_R0": BatchQueryEngine(g, None, QueryConfig(
            mode="verd", t_iterations=7, top_k=50)),
        "powerwalk_R10": BatchQueryEngine(g, idx10, QueryConfig(
            mode="powerwalk", t_iterations=5, top_k=50)),
        "powerwalk_R100": BatchQueryEngine(g, idx100, QueryConfig(
            mode="powerwalk", t_iterations=2, top_k=50)),
    }

    batches = [1, 100, 1000] if fast else [1, 100, 1000, 4000]
    for name, eng in engines.items():
        for nq in batches:
            if name == "pi" and nq > 100:
                continue  # the paper's PI cannot handle big batches either
            qs = rng.integers(0, g.n, size=nq).astype(np.int32)
            res = eng.run(qs)          # includes compile on first call
            res2 = eng.run(qs)         # steady-state
            out[(name, nq)] = res2["seconds"]
            emit(
                f"table3_{name}_q{nq}",
                res2["seconds"] / nq * 1e6,
                f"total_s={res2['seconds']:.4f};qps={res2['qps']:.1f}",
            )
    out.update(run_sparse_sweep(fast=fast))
    out.update(run_exchange_report(fast=fast))
    return out


# ---------------------------------------------------------------------------
# distributed exchange wire bytes: dense slab vs SparseFrontier wire format
# ---------------------------------------------------------------------------

def run_exchange_report(fast: bool = False) -> dict:
    """Per-iteration wire bytes each shard puts on the ``all_to_all``.

    The headline point (n=100k, Q=256, K=512, 4 shards) is the acceptance
    gate of the sparse-exchange refactor: >= 5x fewer bytes than the dense
    slab.  Analytic from the exchange shapes (exact — the buffers are
    fixed-width), so the report also covers pod-scale configs this
    container cannot run.
    """
    points = [(100_000, 256, 512, 4)]
    if not fast:
        points += [(1_000_000, 4096, 512, 16), (41_652_230, 4096, 667, 64)]
    out = {}
    for n, q, k, ep in points:
        cfg = DistConfig(
            n=((n + ep - 1) // ep) * ep, ep=ep, q_tile=q,
            frontier_k=k, wire_k=k, degree_cap=1,
        )
        b = exchange_bytes_per_iteration(cfg)
        out[("exchange", n, q, k, ep)] = b
        emit(
            f"exchange_bytes_n{n}_q{q}_k{k}_ep{ep}",
            b["sparse"],
            f"dense_B={b['dense']:.3e};sparse_B={b['sparse']:.3e};"
            f"reduction={b['reduction']:.1f}x",
        )
    return out


# ---------------------------------------------------------------------------
# dense vs sparse frontier path (the Q x n -> Q x K refactor)
# ---------------------------------------------------------------------------

def _random_index(n: int, l: int, key: jax.Array) -> PPRIndex:
    """Synthetic sub-stochastic top-L index: building a real MCFP index for
    a 100k-vertex graph would dominate the benchmark; path-relative speed
    and L1-vs-dense-oracle do not depend on the index contents."""
    kv, ki = jax.random.split(key)
    vals = jax.random.uniform(kv, (n, l), jnp.float32)
    vals = jnp.sort(vals / vals.sum(axis=1, keepdims=True), axis=1)[:, ::-1]
    idxs = jax.random.randint(ki, (n, l), 0, n, jnp.int32)
    return PPRIndex(values=vals, indices=idxs, l=l, n=n)


def run_sparse_sweep(fast: bool = False) -> dict:
    """Wall-clock + L1 sweep over (n, Q, K): dense oracle vs sparse path.

    The headline point (n=100k, Q=256, K=512) reproduces the acceptance gate
    of the sparse-frontier refactor: >= 5x on the shared-decomposition query
    with L1-vs-dense bounded by the truncated frontier mass.
    """
    points = [(20_000, 64, 128)]
    if not fast:
        # 8_192 / 16_384 bracket AUTO_SPARSE_MIN_N (1 << 14): the recorded
        # crossover evidence behind the retuned auto threshold
        points += [(8_192, 64, 128), (16_384, 64, 128),
                   (100_000, 256, 512), (100_000, 256, 128)]
    out = {}
    setups = {}  # graph + index per unique n (construction is the slow part)
    for n, q, k in points:
        if n not in setups:
            setups[n] = (
                synthetic.erdos_renyi(n, 8.0, seed=5),
                _random_index(n, 32, jax.random.PRNGKey(7)),
            )
        g, idx = setups[n]
        srcs = jnp.asarray(
            np.random.default_rng(0).integers(0, n, q), jnp.int32
        )
        kw = dict(mode="powerwalk", t_iterations=2, top_k=100, frontier_k=k)
        dense = BatchQueryEngine(
            g, idx, QueryConfig(frontier_path="dense", **kw))
        sparse = BatchQueryEngine(
            g, idx, QueryConfig(frontier_path="sparse", **kw))
        t_dense = timeit(lambda: dense.query_topk(srcs))
        t_sparse = timeit(lambda: sparse.query_topk(srcs))
        # L1 vs the dense oracle (full vectors, not just top-k)
        oracle = dense.query_dense(srcs)
        approx = sparse.query_sparse(srcs, out_k=min(8 * k, n)).densify()
        l1 = float(jnp.abs(approx - oracle).sum(axis=1).mean())
        speedup = t_dense / max(t_sparse, 1e-9)
        out[(n, q, k)] = dict(
            t_dense=t_dense, t_sparse=t_sparse, speedup=speedup, l1=l1
        )
        emit(
            f"sparse_sweep_n{n}_q{q}_k{k}",
            t_sparse / q * 1e6,  # per query, like every other row here
            f"dense_s={t_dense:.4f};sparse_s={t_sparse:.4f};"
            f"speedup={speedup:.1f}x;l1_vs_dense={l1:.2e}",
        )
    return out


if __name__ == "__main__":
    run()
