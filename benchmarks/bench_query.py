"""Paper Table 3 + Figure 6: online query latency vs batch size and method.

Methods: PI, online MCFP, FPPR (direct index lookup), PowerWalk at
R in {0, 10, 100}.  Batch sizes scaled to the CPU-tier graph.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_graph, emit
from repro.core.index import build_index
from repro.core.query import BatchQueryEngine, QueryConfig


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    key = jax.random.PRNGKey(3)
    rng = np.random.default_rng(0)
    out = {}

    idx10, _ = build_index(g, r=10, l=67, key=key, source_batch=512)
    idx100, _ = build_index(g, r=100, l=256, key=key, source_batch=512)

    engines = {
        "pi": BatchQueryEngine(g, None, QueryConfig(
            mode="pi", pi_iterations=50, top_k=50)),
        "mcfp_online": BatchQueryEngine(g, None, QueryConfig(
            mode="mcfp", r_online=1000, top_k=50)),
        "fppr": BatchQueryEngine(g, idx100, QueryConfig(
            mode="fppr", top_k=50)),
        "powerwalk_R0": BatchQueryEngine(g, None, QueryConfig(
            mode="verd", t_iterations=7, top_k=50)),
        "powerwalk_R10": BatchQueryEngine(g, idx10, QueryConfig(
            mode="powerwalk", t_iterations=5, top_k=50)),
        "powerwalk_R100": BatchQueryEngine(g, idx100, QueryConfig(
            mode="powerwalk", t_iterations=2, top_k=50)),
    }

    batches = [1, 100, 1000] if fast else [1, 100, 1000, 4000]
    for name, eng in engines.items():
        for nq in batches:
            if name == "pi" and nq > 100:
                continue  # the paper's PI cannot handle big batches either
            qs = rng.integers(0, g.n, size=nq).astype(np.int32)
            res = eng.run(qs)          # includes compile on first call
            res2 = eng.run(qs)         # steady-state
            out[(name, nq)] = res2["seconds"]
            emit(
                f"table3_{name}_q{nq}",
                res2["seconds"] / nq * 1e6,
                f"total_s={res2['seconds']:.4f};qps={res2['qps']:.1f}",
            )
    return out


if __name__ == "__main__":
    run()
