"""Paper Figure 5: VERD accuracy vs iterations T at index R in {0, 10, 100}.

The paper's calibration: RAG > 0.99 needs T = 7 / 5 / 2 at R = 0 / 10 / 100.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, emit, ground_truth, paper_sources, rag, timeit
from repro.core import verd
from repro.core.index import build_index


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    sources = paper_sources(g, per_bucket=3 if fast else 5)
    exact = ground_truth(g, sources)
    src = jnp.asarray(sources, jnp.int32)
    key = jax.random.PRNGKey(1)
    k = 50
    out = {}

    indexes = {0: None}
    for r in (10, 100):
        idx, stats = build_index(
            g, r=r, l=max(16, int(r / 0.15)), key=key,
            source_batch=512,
        )
        indexes[r] = idx
        emit(f"fig5_index_R{r}_build", 0.0,
             f"bytes={stats['nbytes']};drop={stats['drop_fraction']:.4f}")

    t_values = [0, 1, 2, 3, 5, 7] if not fast else [0, 2, 5]
    for r, idx in indexes.items():
        for t in t_values:
            if r == 0 and t == 0:
                continue
            sec = timeit(
                lambda: verd.verd_query(g, src, idx, t=t), iters=1
            )
            got = verd.verd_query(g, src, idx, t=t)
            rr = rag(exact, got, k)
            out[(r, t)] = rr
            emit(f"fig5_verd_R{r}_T{t}", sec * 1e6, f"rag@{k}={rr:.4f}")
    return out


if __name__ == "__main__":
    run()
