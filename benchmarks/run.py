"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only walks,...]

Output: ``name,us_per_call,derived`` CSV rows (one per measurement) on
stdout, plus one ``BENCH_<module>.json`` file per module whose ``run()``
returns a dict (positions/sec, peak state bytes, wall times, ...) — the
persisted perf trajectory, so speedups claimed in one PR are checkable in
the next.
Mapping to the paper:
  bench_accuracy   -> Figures 3-4 (MCFP vs MCEP)
  bench_verd       -> Figure 5    (VERD iterations vs index R)
  bench_preprocess -> Table 2     (offline indexing cost; analytic big rows)
  bench_query      -> Table 3 / Figure 6 (online batch-query latency)
  bench_walks      -> Section 3.1 (walk-engine throughput, legacy vs sparse)
  bench_kernels    -> Pallas kernel micro-benches + correctness gates
  bench_serving    -> Section 3.3 serving loop (open-loop QPS, pipeline depth)
  bench_cache      -> answer cache under Zipf hot-seed traffic (knee shift)
  bench_updates    -> evolving-graph maintenance (incremental vs rebuild)
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _json_safe(obj):
    """Coerce a benchmark result into JSON-serializable form (tuple keys
    become strings, arrays become lists, unknowns become repr strings)."""
    if isinstance(obj, dict):
        return {
            k if isinstance(k, str) else str(k): _json_safe(v)
            for k, v in obj.items()
        }
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    if hasattr(obj, "tolist"):
        return _json_safe(obj.tolist())
    if hasattr(obj, "item"):
        return obj.item()
    return repr(obj)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graphs / fewer points (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the BENCH_<module>.json files")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_cache, bench_kernels,
                            bench_preprocess, bench_query, bench_serving,
                            bench_updates, bench_verd, bench_walks)
    modules = dict(
        accuracy=bench_accuracy, verd=bench_verd, preprocess=bench_preprocess,
        query=bench_query, walks=bench_walks, kernels=bench_kernels,
        serving=bench_serving, cache=bench_cache, updates=bench_updates,
    )
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for name, mod in modules.items():
        print(f"# --- {name} ---", flush=True)
        t_mod = time.time()
        try:
            result = mod.run(fast=args.fast)
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"# FAILED {name}: {type(e).__name__}: {e}", flush=True)
            continue
        if isinstance(result, dict):
            import os

            payload = _json_safe(result)
            payload["_meta"] = dict(
                module=name, fast=bool(args.fast),
                wall_s=time.time() - t_mod,
            )
            # --fast measures CI-sized graphs: keep it from clobbering the
            # persisted full-size perf trajectory
            suffix = ".fast.json" if args.fast else ".json"
            path = os.path.join(args.json_dir, f"BENCH_{name}{suffix}")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"# wrote {path}", flush=True)
    print(f"# total_seconds={time.time() - t0:.1f} failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
