"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Output: ``name,us_per_call,derived`` CSV rows (one per measurement).
Mapping to the paper:
  bench_accuracy   -> Figures 3-4 (MCFP vs MCEP)
  bench_verd       -> Figure 5    (VERD iterations vs index R)
  bench_preprocess -> Table 2     (offline indexing cost; analytic big rows)
  bench_query      -> Table 3 / Figure 6 (online batch-query latency)
  bench_walks      -> Section 3.1 (walk-engine throughput)
  bench_kernels    -> Pallas kernel micro-benches + correctness gates
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graphs / fewer points (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_kernels, bench_preprocess,
                            bench_query, bench_verd, bench_walks)
    modules = dict(
        accuracy=bench_accuracy, verd=bench_verd, preprocess=bench_preprocess,
        query=bench_query, walks=bench_walks, kernels=bench_kernels,
    )
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for name, mod in modules.items():
        print(f"# --- {name} ---", flush=True)
        try:
            mod.run(fast=args.fast)
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"# FAILED {name}: {type(e).__name__}: {e}", flush=True)
    print(f"# total_seconds={time.time() - t0:.1f} failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
