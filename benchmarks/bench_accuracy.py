"""Paper Figures 3 & 4: MCFP vs MCEP accuracy.

Fig 3: RAG@200 vs R (walks per source) for both estimators.
Fig 4: RAG vs k at matched sample budgets (MCFP R=1000 ~ MCEP R=6700).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, emit, ground_truth, paper_sources, rag, timeit
from repro.core import mcep, mcfp


def run(fast: bool = False) -> dict:
    g = bench_graph("tiny" if fast else "wiki_like")
    sources = paper_sources(g, per_bucket=3 if fast else 5)
    exact = ground_truth(g, sources)
    key = jax.random.PRNGKey(0)
    src = jnp.asarray(sources, jnp.int32)
    out = {}

    # -- Fig 3: RAG@k vs R ---------------------------------------------------
    k = 50
    r_values = [100, 400, 1000] if fast else [100, 400, 1000, 2000]
    for r in r_values:
        t_fp = timeit(lambda: mcfp.estimate_ppr(g, src, r, key), iters=1)
        est_fp = mcfp.estimate_ppr(g, src, r, key)
        est_ep = mcep.estimate_ppr(g, src, r, key)
        rag_fp = rag(exact, est_fp, k)
        rag_ep = rag(exact, est_ep, k)
        out[f"R{r}"] = (rag_fp, rag_ep)
        emit(f"fig3_mcfp_R{r}", t_fp * 1e6, f"rag@{k}={rag_fp:.4f}")
        emit(f"fig3_mcep_R{r}", t_fp * 1e6, f"rag@{k}={rag_ep:.4f}")

    # -- Fig 4: matched budgets (MCFP R vs MCEP R/c) --------------------------
    r = 600 if fast else 1000
    r_ep = int(r / 0.15)
    est_fp = mcfp.estimate_ppr(g, src, r, key)
    est_ep = mcep.estimate_ppr(g, src, r_ep, key)
    for k in (10, 50, 200):
        rf, re = rag(exact, est_fp, k), rag(exact, est_ep, k)
        out[f"fig4_k{k}"] = (rf, re)
        emit(f"fig4_matched_k{k}", 0.0,
             f"mcfp_R{r}={rf:.4f};mcep_R{r_ep}={re:.4f}")
    return out


if __name__ == "__main__":
    run()
