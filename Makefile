# Developer entry points.  Everything pins PYTHONPATH=src so the targets work
# from a clean checkout without an editable install.

PY ?= python

.PHONY: test test-fast test-faults test-parity test-kernels lint-contracts \
	bench bench-smoke \
	bench-walks bench-preprocess-dist bench-serving bench-serving-smoke \
	bench-cache bench-cache-smoke bench-updates bench-updates-smoke

# tier-1 verify: the full suite (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# quick subset: skips tests marked `slow` (see pytest.ini) — still includes
# the fast half of the crash-safety suite (in-process fault injection).
# Runs the contract auditor first: a layout/sync regression fails in
# seconds, before any test executes.
test-fast: lint-contracts
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

# contract auditor (docs/static_analysis.md): jaxpr rules (hbm-residency,
# no-replicated-index, dense-state-bound, retrace-guard) + AST lint
# (host-sync, rng-discipline, bare-time).  Nonzero exit on any unsuppressed
# finding; `--only <rule>` / `--json` for CI annotation.
lint-contracts:
	PYTHONPATH=src $(PY) -m repro.analysis

# crash-safety suite: checkpoint store unit tests + resumable-build bitwise
# parity, incl. the slow subprocess SIGKILL sweep (docs/indexing_path.md,
# "Crash safety & resume")
test-faults:
	PYTHONPATH=src $(PY) -m pytest -x -q \
		tests/test_checkpoint.py tests/test_checkpoint_resume.py

# cross-path parity: distributed-sparse vs single-device-sparse vs dense
# oracle, incl. the slow 4-shard subprocess half (docs/query_path.md)
test-parity:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_parity.py

# kernel-contract suite: every DMA-gather kernel vs its dense oracle in
# interpret mode (tpu-marked interpret=False cases auto-skip off-TPU)
test-kernels:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_kernels.py

# full paper-table benchmark sweep
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# CI-sized smoke: small graphs — query + kernel tables plus the cache
# knee-shift and evolving-graph update smokes (the fast suite's bench half)
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast \
		--only query,kernels,cache,updates

# serving pipeline: open-loop QPS sweep + depth sweep at the n=100k/K=512
# reference point; writes BENCH_serving.json (docs/serving_path.md)
bench-serving:
	PYTHONPATH=src $(PY) -m benchmarks.run --only serving

# CI-sized serving smoke: writes BENCH_serving.fast.json so the full-size
# trajectory is never clobbered (PR-4 convention)
bench-serving-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast --only serving

# answer cache: Zipf hot-seed traffic x cache size at the n=100k/K=512
# reference point; writes BENCH_cache.json (knee shift vs cache-off,
# >= 1.5x gate at skew 1.1 — docs/serving_path.md)
bench-cache:
	PYTHONPATH=src $(PY) -m benchmarks.run --only cache

# CI-sized cache smoke: writes BENCH_cache.fast.json
bench-cache-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast --only cache

# evolving-graph maintenance: incremental repair vs full rebuild over an
# edge-update sequence at n=32k; writes BENCH_updates.json (>= 10x fewer
# resampled positions at <= 2x drift — docs/indexing_path.md)
bench-updates:
	PYTHONPATH=src $(PY) -m benchmarks.run --only updates

# CI-sized update smoke: writes BENCH_updates.fast.json
bench-updates-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast --only updates

# offline walk engine: legacy vs compacted-sparse positions/sec at the
# n=100k acceptance point + index-build timings; writes BENCH_walks.json
# and BENCH_preprocess.json (docs/indexing_path.md)
bench-walks:
	PYTHONPATH=src $(PY) -m benchmarks.run --only walks,preprocess

# sharded offline build on a host-simulated 4-device CPU mesh: records the
# build_index_sharded rows (schedule vs respawn scheduling — the >= 2x
# respawn gate at r=16) into BENCH_preprocess.json's dist section
bench-preprocess-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
		$(PY) -m benchmarks.run --only preprocess
